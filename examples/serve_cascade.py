"""End-to-end serving driver: a real JAX-executed cascade, latencies
measured on THIS machine (replacing the paper's A100 profiling), then the
full DiffServe control loop replays a bursty trace against those profiles.

Builds one toy UNet per tier of the chosen cascade, so 3-tier registries
(`sdxs3`, `sdxl3`) run the full tier-recursive pipeline. Heterogeneous
clusters split the workers into speed classes; the allocator plans over
``x[tier][class]`` and the report shows the per-class split.

Two modes share one ControlPlane (serving/controlplane.py):

  --mode sim      measured profiles feed the discrete-event simulator
                  backend (default; the paper's own methodology)
  --mode cluster  the ClusterBackend really executes every batch on the
                  jitted stages: measured per-class profiles feed
                  solve_heterogeneous_cascade re-planning every control
                  tick, confidences come from the real discriminator

  PYTHONPATH=src python examples/serve_cascade.py
  PYTHONPATH=src python examples/serve_cascade.py --mode cluster \
      --cascade sdturbo --worker-classes a100:2:1.0,a10g:6:0.45
  PYTHONPATH=src python examples/serve_cascade.py \
      --cascade sdxs3 --controller diffserve --estimator sliding-window
  PYTHONPATH=src python examples/serve_cascade.py --mode cluster \
      --cascade sdxs3 --controller cascade-search
      # per-epoch cascade search over the measured spec's sub-chains:
      # the backend may switch cascades mid-run (staged slice reload)
"""
import argparse
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import DiffusionConfig, as_cascade_spec
from repro.core.cascade import DiffusionCascade
from repro.models.unet import init_unet
from repro.core.quality import load_quality_models, save_quality_models
from repro.serving.baselines import CONTROLLERS, assemble_bundle
from repro.serving.cluster import (ClusterBackend, ClusterRuntime,
                                   measured_worker_classes)
from repro.kernels.impls import KERNEL_IMPLS
from repro.serving.controlplane import ESTIMATORS
from repro.serving.microserve import STAGES
from repro.serving.profiles import (CASCADES, class_costs_from_arg,
                                    default_serving, worker_classes_from_arg)
from repro.serving.simulator import SimConfig, Simulator
from repro.serving.trace import azure_like_trace

ap = argparse.ArgumentParser()
ap.add_argument("--cascade", default="sdturbo", choices=sorted(CASCADES))
ap.add_argument("--mode", default="sim", choices=("sim", "cluster"),
                help="sim: measured profiles drive the simulator backend; "
                "cluster: the ClusterBackend really executes every batch")
ap.add_argument("--controller", default="diffserve",
                choices=sorted(CONTROLLERS),
                help="control-plane policy bundle (serving/baselines.py)")
ap.add_argument("--estimator", default=None, choices=sorted(ESTIMATORS),
                help="demand estimator (default: the serving config's, "
                "i.e. ewma)")
ap.add_argument("--workers", type=int, default=8)
ap.add_argument("--worker-classes", default=None,
                help="name:count[:speed][@model=BASExMARG],... e.g. "
                "a100:2:1.0,a10g:6:0.45 (overrides --workers)")
ap.add_argument("--cost-per-class", default=None,
                help="$/hour per class as name[=cost],... — switches the "
                "allocator to the cost-weighted objective")
ap.add_argument("--stage-graph", default="off", choices=sorted(STAGES),
                help="stage-granular micro-serving: in cluster mode the "
                "discriminator decouples onto per-boundary disc queues "
                "drained by the cheapest class present")
ap.add_argument("--stage-denoise-steps", type=int, default=8,
                help="micro stage graph: denoise steps per tier")
ap.add_argument("--stage-preempt-frac", type=float, default=0.5,
                help="micro stage graph: earliest preemption fraction")
ap.add_argument("--kernel-impl", default="auto",
                choices=sorted(KERNEL_IMPLS),
                help="kernel hot path for the jitted stages: auto / "
                "pallas / interpret / ref / xla (unfused baseline)")
ap.add_argument("--batch-buckets", default="1,2,4,8",
                help="batch bucket ladder samplers pad to (empty string "
                "disables bucketing)")
ap.add_argument("--save-quality-models", default=None,
                help="cluster mode: persist per-boundary quality models "
                "fitted from this run's real discriminator confidences "
                "as JSON (core/quality.py round-trip)")
ap.add_argument("--quality-models", default=None,
                help="seed the control plane's deferral profiles from a "
                "saved quality-models JSON instead of the synthetic "
                "offline fit")
ap.add_argument("--duration", type=int, default=90)
ap.add_argument("--seed", type=int, default=1)
args = ap.parse_args()

wcs = (worker_classes_from_arg(args.worker_classes)
       if args.worker_classes else ())
if args.cost_per_class and not wcs:
    ap.error("--cost-per-class requires --worker-classes")
costs = (class_costs_from_arg(args.cost_per_class)
         if args.cost_per_class else ())
serving = default_serving(cascade=args.cascade, num_workers=args.workers,
                          worker_classes=wcs, class_costs=costs,
                          controller=args.controller,
                          estimator=args.estimator or "ewma",
                          stage_graph=args.stage_graph,
                          stage_denoise_steps=args.stage_denoise_steps,
                          stage_preempt_frac=args.stage_preempt_frac,
                          kernel_impl=args.kernel_impl,
                          batch_buckets=tuple(
                              int(b) for b in args.batch_buckets.split(",")
                              if b.strip()))
spec = as_cascade_spec(serving.cascade)
n_tiers = spec.num_tiers

key = jax.random.PRNGKey(args.seed)
keys = jax.random.split(key, n_tiers + 1)
stages = []
for i in range(n_tiers):
    # deeper tiers: wider UNet, more sampler steps (cheap -> heavy)
    cfg = DiffusionConfig(
        name=f"toy-tier{i}", image_size=16, in_channels=3,
        base_channels=16 + 8 * i, channel_mults=(1, 2),
        num_res_blocks=1 if i == 0 else 2, attn_resolutions=(),
        num_steps=max(1, round(1 + 7 * i / max(n_tiers - 1, 1))),
        text_dim=32)
    stages.append((cfg, init_unet(keys[i], cfg)))

from repro.training.discriminator import train_discriminator  # noqa: E402
disc_params, disc_cfg, _ = train_discriminator(keys[-1], steps=40,
                                               batch_size=16,
                                               image_size=16, lr=3e-3)
cascade = DiffusionCascade(stages, disc_cfg, disc_params)

runtime = ClusterRuntime(cascade, serving)
print("measuring on-device execution profiles ...")
prof = runtime.measure_profile(batches=(1, 2))
print([(round(p.base_s, 4), round(p.marginal_s, 4)) for p in prof])

# feed measured per-tier profiles into the controller and serve a trace
tiers = tuple(dataclasses.replace(t, profile=prof[i])
              for i, t in enumerate(spec.tiers))
spec = dataclasses.replace(spec, tiers=tiers,
                           slo_s=max(10 * prof[-1].base_s, 1.0))
serving = dataclasses.replace(serving, cascade=spec)
if args.mode == "cluster" and wcs:
    # measured per-class e(b) tables (once per class present in slices)
    # replace the static GPU latency-scale table in the solver
    class_profs = runtime.measure_class_profiles(batches=(1, 2))
    serving = dataclasses.replace(
        serving, worker_classes=measured_worker_classes(serving,
                                                        class_profs))
if args.mode == "cluster":
    # every plan batch size must already be warm (measure_profile jitted
    # b=1,2), so re-planning never stalls on a fresh XLA compile
    serving = dataclasses.replace(serving, batch_choices=(1, 2))
    runtime = ClusterRuntime(cascade, serving)

# capacity in speed-weighted worker-equivalents (a10g:0.45 is not an a100)
worker_eq = (sum(wc.count * wc.speed for wc in wcs) if wcs
             else serving.num_workers)
cap = worker_eq / prof[0].base_s * 0.25
trace = azure_like_trace(args.duration, seed=2).scale(max(cap / 8, 0.5),
                                                      max(cap, 1.0))

# one shared assembly path with run_controller: bundle fields (fixed
# plan, allocator ablation mode, random-confidence RNG) cannot drift
loaded_profiles = None
if args.quality_models:
    loaded_models = load_quality_models(args.quality_models)
    loaded_profiles = tuple(m.deferral_profile() for m in loaded_models)
bundle, profiles, fixed, control, bundle_conf = assemble_bundle(
    args.controller, trace, serving, seed=0, estimator=args.estimator,
    profiles=loaded_profiles)
# query-agnostic bundles (Proteus) route on the bundle's random
# confidences; the others score with the really-trained discriminator
real_conf = lambda n: np.asarray(cascade.confidence(     # noqa: E731
    jnp.asarray(np.random.default_rng(0).normal(
        size=(n, 16, 16, 3)).astype(np.float32))))

if args.mode == "cluster":
    backend = ClusterBackend(
        runtime, serving, profiles, seed=0, router=bundle.router,
        arrival_stage=bundle.arrival_stage, confidence_fn=bundle_conf)
    r = backend.serve(control, trace)
else:
    sim = Simulator(serving, profiles,
                    SimConfig(seed=0, router=bundle.router,
                              arrival_stage=bundle.arrival_stage,
                              fixed_plan=fixed),
                    control=control,
                    confidence_fn=bundle_conf or real_conf)
    r = sim.run(trace)

report = {
    "mode": args.mode,
    "cascade": args.cascade,
    "controller": args.controller,
    "estimator": args.estimator or serving.estimator,
    "tiers": [t.model for t in spec.tiers],
    "workers": serving.num_workers,
    "served": r.completed, "total": r.total,
    "slo_violation_ratio": round(r.violation_ratio, 3),
    "defer_fraction": round(r.defer_fraction, 2),
    "fid_star": round(r.mean_fid, 2),
}
if wcs:
    report["worker_classes"] = {wc.name: {"count": wc.count,
                                          "speed": wc.speed} for wc in wcs}
    report["workers_by_class"] = r.workers_by_class
    report["class_mean_batch_latency_s"] = r.class_latency_summary()
if args.mode == "cluster":
    if wcs:
        report["measured_class_scales"] = {
            wc.name: {m: [round(sc.base, 3), round(sc.marginal, 3)]
                      for m, sc in wc.profiles}
            for wc in serving.worker_classes}
    plans = backend.plan_timeline
    report["control_ticks"] = len(plans)
    report["distinct_plans"] = len({p[1:] for p in plans})
    report["plan_timeline_head"] = [
        {"t": round(t, 1), "workers": list(w), "batches": list(b)}
        for t, w, b in plans[:8]]
    if args.stage_graph != "off":
        report["stage_graph"] = args.stage_graph
        report["disc_class"] = backend.disc_class or "(homogeneous)"
    if args.save_quality_models:
        models = backend.fitted_quality_models()
        save_quality_models(args.save_quality_models, models)
        report["saved_quality_models"] = args.save_quality_models
        report["quality_model_samples"] = [
            len(s) for s in backend._conf_samples]
if args.quality_models:
    report["quality_models"] = args.quality_models
if costs and r.plan_cost_timeline:
    report["mean_cost_per_hour"] = round(r.mean_plan_cost_per_hour, 3)
if r.cascade_timeline:
    report["cascade_switches"] = r.cascade_switches
    report["cascade_timeline"] = [[round(t, 1), n]
                                  for t, n in r.cascade_timeline]
print(json.dumps(report, indent=1))
