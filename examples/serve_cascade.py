"""End-to-end serving driver: a real JAX-executed cascade, latencies
measured on THIS machine (replacing the paper's A100 profiling), then the
full DiffServe control loop replays a bursty trace against those profiles.

  PYTHONPATH=src python examples/serve_cascade.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import DiffusionConfig, as_cascade_spec
from repro.core.cascade import DiffusionCascade
from repro.models.unet import init_unet
from repro.serving.baselines import make_profile
from repro.serving.cluster import ClusterRuntime
from repro.serving.profiles import default_serving
from repro.serving.simulator import SimConfig, Simulator
from repro.serving.trace import azure_like_trace
from repro.training.discriminator import train_discriminator

key = jax.random.PRNGKey(1)
light_cfg = DiffusionConfig(name="toy-turbo", image_size=16, in_channels=3,
                            base_channels=16, channel_mults=(1, 2),
                            num_res_blocks=1, attn_resolutions=(),
                            num_steps=1, text_dim=32)
heavy_cfg = DiffusionConfig(name="toy-sd", image_size=16, in_channels=3,
                            base_channels=24, channel_mults=(1, 2),
                            num_res_blocks=2, attn_resolutions=(),
                            num_steps=8, text_dim=32)
kl, kh, kd = jax.random.split(key, 3)
disc_params, disc_cfg, _ = train_discriminator(kd, steps=40, batch_size=16,
                                               image_size=16, lr=3e-3)
cascade = DiffusionCascade([(light_cfg, init_unet(kl, light_cfg)),
                            (heavy_cfg, init_unet(kh, heavy_cfg))],
                           disc_cfg, disc_params)

serving = default_serving("sdturbo", num_workers=8)
runtime = ClusterRuntime(cascade, serving)
print("measuring on-device execution profiles ...")
prof = runtime.measure_profile(batches=(1, 2))
print([(round(p.base_s, 4), round(p.marginal_s, 4)) for p in prof])

# feed measured per-tier profiles into the controller and serve a trace
spec = as_cascade_spec(serving.cascade)
tiers = tuple(dataclasses.replace(t, profile=prof[i])
              for i, t in enumerate(spec.tiers))
spec = dataclasses.replace(spec, tiers=tiers,
                           slo_s=max(10 * prof[-1].base_s, 1.0))
serving = dataclasses.replace(serving, cascade=spec)
cap = serving.num_workers / prof[0].base_s * 0.25
trace = azure_like_trace(90, seed=2).scale(max(cap / 8, 0.5), max(cap, 1.0))
sim = Simulator(serving, make_profile(serving, 0),
                SimConfig(seed=0, router="discriminator"),
                confidence_fn=lambda n: np.asarray(cascade.confidence(
                    jnp.asarray(np.random.default_rng(0).normal(
                        size=(n, 16, 16, 3)).astype(np.float32)))))
r = sim.run(trace)
print(f"served {r.completed}/{r.total} queries | "
      f"SLO violations {r.violation_ratio:.3f} | "
      f"defer fraction {r.defer_fraction:.2f} | FID* {r.mean_fid:.2f}")
