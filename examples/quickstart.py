"""Quickstart: build a diffusion cascade, train its discriminator, route a
batch of queries through it, and solve the allocation MILP — in ~2 minutes
on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import DiffusionConfig
from repro.core.cascade import DiffusionCascade
from repro.core.confidence import DeferralProfile
from repro.core.milp import solve_allocation
from repro.models.unet import init_unet
from repro.serving.profiles import default_serving
from repro.training.discriminator import train_discriminator

key = jax.random.PRNGKey(0)

# 1. Two diffusion model variants: light (1-step) and heavy (8-step).
light_cfg = DiffusionConfig(name="toy-turbo", image_size=16, in_channels=3,
                            base_channels=16, channel_mults=(1, 2),
                            num_res_blocks=1, attn_resolutions=(8,),
                            num_steps=1, text_dim=32)
heavy_cfg = DiffusionConfig(name="toy-sd", image_size=16, in_channels=3,
                            base_channels=32, channel_mults=(1, 2),
                            num_res_blocks=2, attn_resolutions=(8,),
                            num_steps=8, text_dim=32)
kl, kh, kd = jax.random.split(key, 3)
light_params = init_unet(kl, light_cfg)
heavy_params = init_unet(kh, heavy_cfg)

# 2. Train the discriminator (real-vs-generated, paper §3.2).
print("training discriminator ...")
disc_params, disc_cfg, hist = train_discriminator(
    kd, steps=80, batch_size=16, image_size=16, lr=3e-3, log_every=40)
print("  final acc:", hist[-1]["acc"])

# 3. Run a batch of queries through the cascade (stages, cheapest first).
cascade = DiffusionCascade([(light_cfg, light_params),
                            (heavy_cfg, heavy_params)],
                           disc_cfg, disc_params)
prompts = jnp.zeros((8, 4), jnp.int32)
result = cascade.run_batch(key, prompts, thresholds=0.5)
print(f"confidences: {np.round(result.confidences, 3)}")
print(f"deferred to heavy: {int(result.deferred.sum())}/8")

# 4. Solve the resource-allocation MILP for 12 QPS on 16 workers.
serving = default_serving("sdturbo", num_workers=16)
profile = DeferralProfile(result.confidences.tolist() * 50)
plan = solve_allocation(serving.cascade, serving, profile, demand_qps=12.0)
print(f"plan: workers={plan.workers}, batches={plan.batches}, "
      f"thresholds={tuple(round(t, 3) for t in plan.thresholds)}, "
      f"solved in {plan.solve_ms:.2f} ms")
