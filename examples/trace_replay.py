"""Replay a dynamic trace against every baseline (paper Fig. 5) with fault
injection and a mid-run snapshot/restore — the fault-tolerance tour.

  PYTHONPATH=src python examples/trace_replay.py
"""
import tempfile

import numpy as np

from repro.serving.baselines import BASELINES, make_profile, run_baseline
from repro.serving.faults import poisson_failures, restore, resume, snapshot
from repro.serving.profiles import default_serving
from repro.serving.simulator import SimConfig, Simulator
from repro.serving.trace import azure_like_trace

serving = default_serving("sdturbo", num_workers=16)
trace = azure_like_trace(240, seed=3).scale(4, 32)

print(f"{'system':18s} {'FID*':>7s} {'SLO-viol':>9s} {'defer':>6s}")
for b in BASELINES:
    r = run_baseline(b, trace, serving, seed=0)
    print(f"{b:18s} {r.mean_fid:7.2f} {r.violation_ratio:9.3f} "
          f"{r.defer_fraction:6.2f}")

# --- fault injection: 4 worker failures + elastic scale-down ---
rng = np.random.default_rng(0)
fails = tuple(poisson_failures(rng, 16, 240.0, mtbf_s=300.0))
sim = Simulator(serving, make_profile(serving, 0),
                SimConfig(seed=0, failure_times=fails,
                          scale_events=((120.0, 12),)))
r = sim.run(trace)
print(f"\nwith {len(fails)} failures + scale-down to 12 workers:")
print(f"  completed {r.completed}/{r.total}, violations "
      f"{r.violation_ratio:.3f}, requeued {r.requeued_on_failure}, "
      f"hedged {r.hedged}")

# --- checkpoint/restart determinism ---
snap = tempfile.mktemp(suffix=".snap")
sim2 = Simulator(serving, make_profile(serving, 0), SimConfig(seed=7))
arrivals = trace.arrivals(sim2.rng)
sim2.result.total = len(arrivals)
from repro.serving.simulator import Query
for i, t in enumerate(arrivals):
    sim2.push(float(t), sim2.ARRIVAL,
              Query(qid=i, arrival=float(t),
                    deadline=float(t) + serving.cascade.slo_s))
sim2.push(0.0, sim2.CONTROL)
sim2._apply_plan_now(first=True)
resume(sim2, end_t=120.0)
snapshot(sim2, snap)
sim3 = Simulator(serving, make_profile(serving, 0), SimConfig(seed=7))
restore(sim3, snap)
final = resume(sim3, end_t=trace.duration_s + 20, final=True)
print(f"\nsnapshot@120s -> restored run completed {final.completed} "
      f"queries, violations {final.violation_ratio:.3f} "
      "(deterministic continuation)")
