"""Thin wrapper: ``python scripts/staticlint.py [paths...]``.

Adds ``src/`` to sys.path so the linter runs from a bare checkout,
then defers to ``python -m repro.analysis.staticlint``.
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

from repro.analysis.staticlint.__main__ import main  # noqa: E402

if __name__ == "__main__":
    raise SystemExit(main())
