"""Capture seeded golden SimResult fields for the control-plane
golden-equivalence suite (tests/test_controlplane.py) and the
builder-parity suite (tests/test_autocascade.py).

Run against the pre-refactor monolith to produce the GOLDEN dict, and
re-run after any intentional behavior change to refresh it:

    PYTHONPATH=src python scripts/capture_golden.py

``--check`` recomputes every fingerprint and diffs it against the
GOLDEN / OVERLOAD_GOLDEN literals committed in the test files (parsed
from source with ``ast.literal_eval`` — nothing is imported from the
tests, nothing is written). Exit 0 = bit-identical, 1 = drift, with a
per-case per-field report. CI and pre-refresh sanity both use it:
an *intended* behavior change should show exactly the cases you meant
to move.

Every case resolves its cascade through the ``CASCADES`` registry, which
since the autocascade refactor is built by ``CascadeBuilder`` over the
builtin ``VariantCatalog`` — so these fingerprints *are* the
builder-parity goldens: any builder/catalog change that alters a pinned
spec shows up here. ``cascade_search_pinned`` additionally pins the
``CascadeSearchPlanner`` restricted to a single candidate to the plain
``SolverPlanner`` behavior (it must equal the ``homogeneous`` case
bit-for-bit; the capture asserts it).
"""
from __future__ import annotations

import argparse
import ast
import pathlib
import pprint
import sys

from repro.config.base import WorkerClass
from repro.serving.baselines import (run_ablation, run_baseline,
                                     run_controller)
from repro.serving.profiles import default_serving
from repro.serving.simulator import SimConfig, Simulator
from repro.serving.trace import azure_like_trace, static_trace
from repro.testing.golden import overload_fingerprint
from repro.testing.golden import sim_fingerprint as fingerprint

REPO = pathlib.Path(__file__).resolve().parent.parent
COMMITTED = (
    (REPO / "tests" / "test_controlplane.py", "GOLDEN"),
    (REPO / "tests" / "test_overload.py", "OVERLOAD_GOLDEN"),
)


def capture():
    """(golden, overload_golden) recomputed from the pinned seeds."""
    golden = {}

    # homogeneous DiffServe on a bursty trace
    sv = default_serving("sdturbo", num_workers=16)
    tr = azure_like_trace(120, seed=3).scale(4, 32)
    golden["homogeneous"] = fingerprint(
        run_baseline("diffserve", tr, sv, seed=0))

    # heterogeneous DiffServe (per-class latency profiles in the solver)
    wcs = (WorkerClass("a100", 2, 1.0), WorkerClass("a10g", 6, 0.45))
    sv_het = default_serving("sdturbo", worker_classes=wcs)
    tr_het = azure_like_trace(90, seed=5).scale(2, 16)
    golden["heterogeneous"] = fingerprint(
        run_baseline("diffserve", tr_het, sv_het, seed=1))

    # fault injection: heartbeat detection + requeue under the control loop
    tr_f = static_trace(10.0, 90)
    sim = Simulator(sv, _profiles(sv),
                    SimConfig(seed=0, failure_times=((20.0, 0, 25.0),
                                                     (25.0, 1, 30.0))))
    golden["fault_injection"] = fingerprint(sim.run(tr_f))

    # fixed-plan / static baselines (never re-plan)
    tr_b = azure_like_trace(90, seed=3).scale(4, 24)
    for name in ("clipper-light", "clipper-heavy", "diffserve-static",
                 "proteus"):
        golden[name] = fingerprint(run_baseline(name, tr_b, sv, seed=0))

    # allocator ablation (AllocatorOptions mode through the planner)
    golden["static_threshold"] = fingerprint(
        run_ablation("static_threshold", tr_b, sv, seed=0))

    # 3-tier cascade (multi-boundary thresholds)
    sv3 = default_serving("sdxs3", num_workers=12)
    golden["three_tier"] = fingerprint(
        run_baseline("diffserve", azure_like_trace(90, seed=7).scale(3, 20),
                     sv3, seed=2))

    # builder parity: CascadeSearchPlanner restricted to one pinned
    # catalog query must reproduce the SolverPlanner homogeneous golden
    # bit-for-bit (tests/test_autocascade.py asserts the same)
    sv_pin = default_serving("sdturbo", num_workers=16,
                             candidate_cascades=("sdturbo",))
    golden["cascade_search_pinned"] = fingerprint(
        run_controller("cascade-search", tr, sv_pin, seed=0))
    assert golden["cascade_search_pinned"] == golden["homogeneous"], \
        "search planner restricted to one cascade diverged from the " \
        "SolverPlanner golden"

    # split drop taxonomy (tests/test_overload.py:OVERLOAD_GOLDEN): the
    # same pinned seeds with the counters broken out per reason, plus one
    # deliberately overloaded queue-depth run so the shed path is pinned
    overload = {
        "homogeneous": overload_fingerprint(
            run_baseline("diffserve", tr, sv, seed=0)),
        "fault_injection": overload_fingerprint(
            Simulator(sv, _profiles(sv),
                      SimConfig(seed=0, failure_times=((20.0, 0, 25.0),
                                                       (25.0, 1, 30.0)))
                      ).run(tr_f)),
        "clipper-heavy": overload_fingerprint(
            run_baseline("clipper-heavy", tr_b, sv, seed=0)),
        "guarded_16x": overload_fingerprint(
            run_controller("diffserve-guarded", tr.scaled(16.0), sv,
                           seed=0)),
    }
    return golden, overload


def committed_golden(path: pathlib.Path, name: str) -> dict:
    """The literal dict assigned to ``name`` in the test file's source.
    Parsed, never imported: reading the goldens must not execute the
    test module (or anything it imports)."""
    tree = ast.parse(path.read_text(), filename=str(path))
    for node in tree.body:
        if isinstance(node, ast.Assign) and any(
                isinstance(t, ast.Name) and t.id == name
                for t in node.targets):
            return ast.literal_eval(node.value)
    raise KeyError(f"no module-level literal {name} = ... in {path}")


def diff_goldens(committed: dict, fresh: dict, label: str) -> int:
    """Print per-case per-field drift; return the number of drifted
    cases. Cases only in the capture (e.g. ``cascade_search_pinned``,
    asserted but not committed) are skipped; committed cases the
    capture no longer produces are drift."""
    drifted = 0
    for case in sorted(committed):
        if case not in fresh:
            print(f"{label}[{case}]: committed but no longer captured")
            drifted += 1
            continue
        want, got = committed[case], fresh[case]
        if want == got:
            continue
        drifted += 1
        fields = sorted(set(want) | set(got))
        for k in fields:
            w, g = want.get(k, "<absent>"), got.get(k, "<absent>")
            if w != g:
                print(f"{label}[{case}].{k}: committed {w!r} != "
                      f"recaptured {g!r}")
    return drifted


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--check", action="store_true",
                    help="recapture and diff against the goldens "
                    "committed in the test files; write nothing; exit "
                    "non-zero on drift")
    args = ap.parse_args(argv)

    golden, overload = capture()
    if not args.check:
        pprint.pprint(golden, width=76, sort_dicts=True)
        print("\nOVERLOAD_GOLDEN = ", end="")
        pprint.pprint(overload, width=76, sort_dicts=True)
        return 0

    fresh = {"GOLDEN": golden, "OVERLOAD_GOLDEN": overload}
    drifted = 0
    for path, name in COMMITTED:
        drifted += diff_goldens(committed_golden(path, name),
                                fresh[name], name)
    if drifted:
        print(f"golden drift: {drifted} case(s) differ "
              "(intentional? re-run without --check and refresh the "
              "test literals)")
        return 1
    print("goldens match: every committed case recaptured bit-identical")
    return 0


def _profiles(sv):
    from repro.serving.baselines import make_profiles
    return make_profiles(sv, 0)


if __name__ == "__main__":
    sys.exit(main())
