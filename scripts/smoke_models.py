"""Dev smoke: tiny forward (train/prefill/decode) for every arch."""
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, reduced_config
from repro.models import kvcache
from repro.models.transformer import forward, init_params, count_params
from repro.configs import get_config

for arch in ARCH_IDS:
    t0 = time.time()
    cfg = reduced_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    B, S = 2, 16
    if cfg.input_mode == "tokens":
        inputs = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    else:
        inputs = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    # train
    logits, _, aux = forward(params, cfg, inputs, mode="train")
    assert logits.shape == (B, S, cfg.vocab_size), logits.shape
    assert not bool(jnp.any(jnp.isnan(logits))), f"{arch}: NaN train logits"
    # prefill
    cache = kvcache.init_cache(cfg, B, max_len=S + 4)
    logits_p, cache, _ = forward(params, cfg, inputs, cache=cache,
                                 cache_index=0, mode="prefill")
    assert not bool(jnp.any(jnp.isnan(logits_p))), f"{arch}: NaN prefill"
    # decode one token
    if cfg.input_mode == "tokens":
        tok = inputs[:, -1:]
    else:
        tok = inputs[:, -1:, :]
    logits_d, cache, _ = forward(params, cfg, tok, cache=cache,
                                 cache_index=S, mode="decode")
    assert logits_d.shape == (B, 1, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits_d))), f"{arch}: NaN decode"
    full = get_config(arch)
    n = count_params(full)
    na = count_params(full, active_only=True)
    print(f"{arch:26s} ok ({time.time()-t0:5.1f}s)  "
          f"full params={n/1e9:8.3f}B active={na/1e9:8.3f}B")
print("ALL OK")
